"""Device-resident client data + per-round index plans.

The vectorized engine's remaining per-round cost (after PR 2 moved the
round computation into one jitted vmap) is host-side: every round
re-materializes the full ``(clients, steps, batch, *features)`` schedule in
numpy and re-uploads O(dataset) bytes host->device, fully serialized with
the round computation.  This module removes that traffic for the lifetime
of a federation:

* ``build_device_cohort`` pads every client's train split to a common
  sample axis and uploads the stacked ``(rows, max_n + 1, *features)``
  arrays **once** (sharded over the mesh's ``"data"`` axis when one is
  given).  Row ``max_n`` of every client is all-zero padding.
* ``build_cohort_plan`` replaces ``build_cohort_schedule`` on the hot
  path: it draws the *same* permutations from the *same* numpy RNG stream
  in the same client-major order, but records only ``(C, T, B)`` int32
  sample indices (plus step validity and weights).  The actual batch
  gather happens on device, inside the jitted round.

Parity is bitwise by construction: a real slot's index points at the same
shuffled sample the schedule would have copied; every padding slot points
at the all-zero pad row, so the gathered batch equals the schedule's
zero-padded batch exactly, and the example mask is recoverable on device
as ``sample_idx < n_c``.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Sequence

import numpy as np

from repro.data.pipeline import ClientDataset, cohort_steps_per_epoch
from repro.obs.trace import resolve_tracer

PyTree = Any

_SCATTER = None


def _scatter_rows(buf: Any, idx: Any, rows: Any) -> Any:
    """Jitted in-place row scatter (donated off-CPU, so no full-array copy)."""
    global _SCATTER
    if _SCATTER is None:
        import jax

        donate = (0,) if jax.default_backend() != "cpu" else ()
        _SCATTER = jax.jit(lambda b, i, r: b.at[i].set(r), donate_argnums=donate)
    return _SCATTER(buf, idx, rows)


@dataclasses.dataclass(frozen=True)
class CohortPlan:
    """A fixed-shape *index* plan for one federated round across a cohort.

    The schedule-shaped twin of ``CohortSchedule``: same ``(C, T)`` step
    grid, same RNG stream, but O(C*T*B) int32 indices instead of O(C*T*B*F)
    feature floats.  ``sample_idx`` entries index a client's *local* sample
    axis in the device-resident cohort; every padding slot (batch tail and
    dummy steps alike) holds ``pad_index``, which every client maps to an
    all-zero row.  ``client_rows`` maps each cohort position to its row in
    the ``DeviceCohort`` the plan will be gathered from.
    """

    sample_idx: np.ndarray  # (C, T, B) int32 into the client's sample axis
    step_valid: np.ndarray  # (C, T) bool — False on dummy padding steps
    client_rows: np.ndarray  # (C,) int32 rows into the DeviceCohort
    weights: np.ndarray     # (C,) float32 local sample counts n_c
    pad_index: int          # the all-zero row every padding slot points at
    steps_per_epoch: int
    local_epochs: int

    @property
    def num_clients(self) -> int:
        return self.sample_idx.shape[0]

    @property
    def total_steps(self) -> int:
        return self.sample_idx.shape[1]

    @property
    def nbytes(self) -> int:
        """Host bytes this plan stages to device per round."""
        return (
            self.sample_idx.nbytes
            + self.step_valid.nbytes
            + self.client_rows.nbytes
            + self.weights.nbytes
        )


@dataclasses.dataclass
class DeviceCohort:
    """A federation's train arrays, resident on device for its lifetime.

    ``x``/``y`` are uploaded once by ``build_device_cohort``; afterwards a
    round stages only a ``CohortPlan`` and the jitted round gathers its
    batches on device.  Sample row ``pad_index`` (== ``x.shape[1] - 1``) is
    all-zero for every client, as are any dummy client rows added to make
    the row axis divide a mesh's data axis.
    """

    x: Any                   # jax.Array (rows, max_n + 1, *features)
    y: Any                   # jax.Array (rows, max_n + 1)
    rows: dict[int, int]     # client_id -> row (current residency when pooled)
    nbytes: int              # resident device bytes (pool bytes when pooled)
    _sources: dict[int, Any] = dataclasses.field(default_factory=dict, repr=False)
    # -- memory-bounded (LRU pool) mode; None/unused when fully resident ----
    pool_rows: int | None = None
    uploads: int = 0
    evictions: int = 0
    hits: int = 0
    bytes_uploaded: int = 0
    _lru: OrderedDict = dataclasses.field(default_factory=OrderedDict, repr=False)
    _free: list = dataclasses.field(default_factory=list, repr=False)
    # Observability: pool uploads record a "pool_upload" span (None = no-op).
    tracer: Any = dataclasses.field(default=None, repr=False)

    def __post_init__(self) -> None:
        self.tracer = resolve_tracer(self.tracer)

    @property
    def pad_index(self) -> int:
        return self.x.shape[1] - 1

    @property
    def num_rows(self) -> int:
        return self.x.shape[0]

    @property
    def is_pooled(self) -> bool:
        return self.pool_rows is not None

    def row_of(self, client: ClientDataset) -> int:
        try:
            return self.rows[client.client_id]
        except KeyError:
            if self.is_pooled:
                raise KeyError(
                    f"client {client.client_id} is not resident in the pool; "
                    "call ensure_resident(round_clients) before staging"
                ) from None
            raise KeyError(
                f"client {client.client_id} is not part of this device cohort; "
                "attach the full federation before training"
            ) from None

    def owns(self, client: ClientDataset) -> bool:
        """True iff this resident copy was built from exactly this dataset."""
        return self._sources.get(client.client_id) is client.train

    def ensure_resident(self, clients: Sequence[ClientDataset]) -> int:
        """Make every client in ``clients`` resident; returns rows uploaded.

        Pool mode only (a fully resident cohort is a no-op).  Runs once per
        round on the consumer thread, *before* any plan is staged: rows are
        then stable for the whole round, so plan prefetch on the staging
        thread never races an eviction.  Eviction is LRU among clients not in
        the current round; the pool must hold the round's whole cohort, which
        is exactly the ``resident_budget_bytes`` contract.
        """
        if not self.is_pooled:
            return 0
        if len(clients) > self.pool_rows:
            raise ValueError(
                f"round cohort of {len(clients)} clients exceeds the resident "
                f"pool ({self.pool_rows} rows); raise resident_budget_bytes or "
                "sample fewer clients per round"
            )
        wanted = {c.client_id for c in clients}
        missing: list[ClientDataset] = []
        for c in clients:
            if not self.owns(c):
                raise KeyError(
                    f"client {c.client_id} was not part of the federation this "
                    "pool was built for"
                )
            if c.client_id in self._lru:
                self._lru.move_to_end(c.client_id)
                self.hits += 1
            else:
                missing.append(c)
        if not missing:
            return 0

        with self.tracer.span("pool_upload", track="pool", missing=len(missing)):
            target_rows: list[int] = []
            for _ in missing:
                if self._free:
                    target_rows.append(self._free.pop())
                    continue
                victim = next(cid for cid in self._lru if cid not in wanted)
                row = self._lru.pop(victim)
                del self.rows[victim]
                self.evictions += 1
                target_rows.append(row)

            max_n = self.pad_index
            hx = np.zeros(
                (len(missing), max_n + 1, *self.x.shape[2:]), dtype=self.x.dtype
            )
            hy = np.zeros((len(missing), max_n + 1), dtype=self.y.dtype)
            for i, c in enumerate(missing):
                n = c.n_train
                hx[i, :n] = c.train.x
                hy[i, :n] = c.train.y
                self._lru[c.client_id] = target_rows[i]
                self.rows[c.client_id] = target_rows[i]
            idx = np.asarray(target_rows, dtype=np.int32)
            self.x = _scatter_rows(self.x, idx, hx)
            self.y = _scatter_rows(self.y, idx, hy)
            self.uploads += len(missing)
            self.bytes_uploaded += hx.nbytes + hy.nbytes
        return len(missing)


def build_device_cohort(
    clients: Sequence[ClientDataset],
    mesh: Any = None,
    resident_budget_bytes: int | None = None,
    tracer: Any = None,
) -> DeviceCohort:
    """Pad and upload every client's train arrays once.

    The sample axis is padded to ``max_n + 1`` so index ``max_n`` is an
    all-zero row shared by every client — the target of every padding slot
    in a ``CohortPlan``.  With a ``mesh`` carrying a ``"data"`` axis the
    row axis is padded to the axis size with all-zero dummy rows and the
    arrays are sharded over it (one ``device_put`` for the whole pytree).

    ``resident_budget_bytes`` bounds device memory for population-scale
    federations: when the fully baked cohort would exceed the budget, only a
    pool of ``budget // row_bytes`` rows is allocated and rows are uploaded
    lazily per round (LRU eviction) via ``ensure_resident`` — a 10^5-client
    population trains out of a pool sized for its round cohorts instead of
    one giant array.  The pool is deliberately single-host: combining it
    with a sharded mesh would re-shard every upload, so that pairing raises.
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    if not clients:
        raise ValueError("empty cohort")
    feat = clients[0].train.x.shape[1:]
    x_dtype = clients[0].train.x.dtype
    y_dtype = clients[0].train.y.dtype
    max_n = max(c.n_train for c in clients)
    shards = 1
    if mesh is not None and "data" in getattr(mesh, "axis_names", ()):
        shards = int(mesh.shape["data"])
    num_rows = len(clients) + (-len(clients) % shards)

    row_bytes = int(
        np.prod((max_n + 1, *feat)) * np.dtype(x_dtype).itemsize
        + (max_n + 1) * np.dtype(y_dtype).itemsize
    )
    full_bytes = num_rows * row_bytes
    if resident_budget_bytes is not None and full_bytes > resident_budget_bytes:
        if shards > 1:
            raise ValueError(
                "resident_budget_bytes pooling is single-host; drop the mesh "
                "or raise the budget to fit the full cohort"
            )
        pool_rows = int(resident_budget_bytes // row_bytes)
        if pool_rows < 1:
            raise ValueError(
                f"resident_budget_bytes={resident_budget_bytes} cannot hold "
                f"even one client row ({row_bytes} bytes)"
            )
        sources: dict[int, Any] = {}
        for client in clients:
            if client.train.x.shape[1:] != feat:
                raise ValueError("all cohort clients must share a feature shape")
            sources[client.client_id] = client.train
        hx = np.zeros((pool_rows, max_n + 1, *feat), dtype=x_dtype)
        hy = np.zeros((pool_rows, max_n + 1), dtype=y_dtype)
        dx, dy = jax.device_put((hx, hy))
        return DeviceCohort(
            x=dx,
            y=dy,
            rows={},
            nbytes=hx.nbytes + hy.nbytes,
            _sources=sources,
            pool_rows=pool_rows,
            _free=list(range(pool_rows - 1, -1, -1)),
            tracer=tracer,
        )

    hx = np.zeros((num_rows, max_n + 1, *feat), dtype=x_dtype)
    hy = np.zeros((num_rows, max_n + 1), dtype=y_dtype)
    rows: dict[int, int] = {}
    sources = {}
    for r, client in enumerate(clients):
        if client.train.x.shape[1:] != feat:
            raise ValueError("all cohort clients must share a feature shape")
        n = client.n_train
        hx[r, :n] = client.train.x
        hy[r, :n] = client.train.y
        rows[client.client_id] = r
        sources[client.client_id] = client.train

    if shards > 1:
        sharding = NamedSharding(mesh, P("data"))
        dx, dy = jax.device_put((hx, hy), sharding)
    else:
        dx, dy = jax.device_put((hx, hy))
    return DeviceCohort(
        x=dx, y=dy, rows=rows, nbytes=hx.nbytes + hy.nbytes, _sources=sources,
        tracer=tracer,
    )


def build_cohort_plan(
    sizes: Sequence[int],
    batch_size: int,
    local_epochs: int,
    rng: np.random.Generator,
    steps_per_epoch: int | None = None,
    client_rows: Sequence[int] | None = None,
    pad_index: int | None = None,
) -> CohortPlan:
    """The index-plan twin of ``build_cohort_schedule``.

    Consumes ``rng`` in exactly the schedule builder's order (client-major,
    one ``rng.permutation(n_c)`` per epoch), so the two paths are fed
    bit-identical shuffles and can be swapped round for round.  Slots the
    schedule would zero-pad (batch tails, dummy steps) point at
    ``pad_index`` — the device cohort's shared all-zero row.
    """
    sizes = [int(n) for n in sizes]
    if not sizes:
        raise ValueError("empty cohort")
    spe = steps_per_epoch or cohort_steps_per_epoch(sizes, batch_size)
    total = spe * local_epochs
    n_clients = len(sizes)
    if pad_index is None:
        pad_index = max(sizes)
    if pad_index < max(sizes):
        raise ValueError(
            f"pad_index={pad_index} must be >= the largest client size {max(sizes)}"
        )

    sample_idx = np.full((n_clients, total, batch_size), pad_index, dtype=np.int32)
    step_valid = np.zeros((n_clients, total), dtype=bool)
    for c, n in enumerate(sizes):
        steps = -(-n // batch_size)
        if steps > spe:
            raise ValueError(f"client {c} needs more than steps_per_epoch={spe} batches")
        for epoch in range(local_epochs):
            perm = rng.permutation(n)
            t = epoch * spe
            for s in range(steps):
                sel = perm[s * batch_size : (s + 1) * batch_size]
                sample_idx[c, t + s, : len(sel)] = sel
                step_valid[c, t + s] = True

    if client_rows is None:
        client_rows = range(n_clients)
    return CohortPlan(
        sample_idx=sample_idx,
        step_valid=step_valid,
        client_rows=np.asarray(list(client_rows), dtype=np.int32),
        weights=np.asarray(sizes, dtype=np.float32),
        pad_index=pad_index,
        steps_per_epoch=spe,
        local_epochs=local_epochs,
    )


def pad_cohort_plan(
    plan: CohortPlan, multiple: int, num_rows: int | None = None
) -> CohortPlan:
    """Pad the client axis with weight-0 dummy clients to a multiple.

    The plan twin of ``pad_cohort_schedule``: dummy clients point every
    slot at the pad row (so they gather all-zero batches with an all-zero
    mask), have no valid steps, zero weight, and borrow row 0 — every one
    of their steps is a masked no-op, so they change only the array shape.

    When ``num_rows`` (the device cohort's row count) is given and the real
    rows form a contiguous run with room after it, dummy clients borrow the
    *continuation* rows instead of row 0: every dummy slot still gathers the
    pad row (all-zero for every client), so the numbers are bit-identical,
    but ``client_rows`` stays contiguous and the static-slice fast path in
    the cohort engine survives padding.
    """
    if multiple <= 1:
        return plan
    pad = -plan.num_clients % multiple
    if pad == 0:
        return plan
    dummy_rows = np.zeros(pad, np.int32)
    rows = plan.client_rows
    if num_rows is not None and rows.size:
        start = int(rows[0])
        contiguous = np.array_equal(
            rows, np.arange(start, start + rows.size, dtype=rows.dtype)
        )
        if contiguous and start + rows.size + pad <= num_rows:
            dummy_rows = np.arange(
                start + rows.size, start + rows.size + pad, dtype=np.int32
            )
    return CohortPlan(
        sample_idx=np.concatenate(
            [
                plan.sample_idx,
                np.full((pad, *plan.sample_idx.shape[1:]), plan.pad_index, np.int32),
            ]
        ),
        step_valid=np.concatenate(
            [plan.step_valid, np.zeros((pad, plan.total_steps), dtype=bool)]
        ),
        client_rows=np.concatenate([plan.client_rows, dummy_rows]),
        weights=np.concatenate([plan.weights, np.zeros(pad, np.float32)]),
        pad_index=plan.pad_index,
        steps_per_epoch=plan.steps_per_epoch,
        local_epochs=plan.local_epochs,
    )
