"""Device-resident client data + per-round index plans.

The vectorized engine's remaining per-round cost (after PR 2 moved the
round computation into one jitted vmap) is host-side: every round
re-materializes the full ``(clients, steps, batch, *features)`` schedule in
numpy and re-uploads O(dataset) bytes host->device, fully serialized with
the round computation.  This module removes that traffic for the lifetime
of a federation:

* ``build_device_cohort`` pads every client's train split to a common
  sample axis and uploads the stacked ``(rows, max_n + 1, *features)``
  arrays **once** (sharded over the mesh's ``"data"`` axis when one is
  given).  Row ``max_n`` of every client is all-zero padding.
* ``build_cohort_plan`` replaces ``build_cohort_schedule`` on the hot
  path: it draws the *same* permutations from the *same* numpy RNG stream
  in the same client-major order, but records only ``(C, T, B)`` int32
  sample indices (plus step validity and weights).  The actual batch
  gather happens on device, inside the jitted round.

Parity is bitwise by construction: a real slot's index points at the same
shuffled sample the schedule would have copied; every padding slot points
at the all-zero pad row, so the gathered batch equals the schedule's
zero-padded batch exactly, and the example mask is recoverable on device
as ``sample_idx < n_c``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import numpy as np

from repro.data.pipeline import ClientDataset, cohort_steps_per_epoch

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CohortPlan:
    """A fixed-shape *index* plan for one federated round across a cohort.

    The schedule-shaped twin of ``CohortSchedule``: same ``(C, T)`` step
    grid, same RNG stream, but O(C*T*B) int32 indices instead of O(C*T*B*F)
    feature floats.  ``sample_idx`` entries index a client's *local* sample
    axis in the device-resident cohort; every padding slot (batch tail and
    dummy steps alike) holds ``pad_index``, which every client maps to an
    all-zero row.  ``client_rows`` maps each cohort position to its row in
    the ``DeviceCohort`` the plan will be gathered from.
    """

    sample_idx: np.ndarray  # (C, T, B) int32 into the client's sample axis
    step_valid: np.ndarray  # (C, T) bool — False on dummy padding steps
    client_rows: np.ndarray  # (C,) int32 rows into the DeviceCohort
    weights: np.ndarray     # (C,) float32 local sample counts n_c
    pad_index: int          # the all-zero row every padding slot points at
    steps_per_epoch: int
    local_epochs: int

    @property
    def num_clients(self) -> int:
        return self.sample_idx.shape[0]

    @property
    def total_steps(self) -> int:
        return self.sample_idx.shape[1]

    @property
    def nbytes(self) -> int:
        """Host bytes this plan stages to device per round."""
        return (
            self.sample_idx.nbytes
            + self.step_valid.nbytes
            + self.client_rows.nbytes
            + self.weights.nbytes
        )


@dataclasses.dataclass
class DeviceCohort:
    """A federation's train arrays, resident on device for its lifetime.

    ``x``/``y`` are uploaded once by ``build_device_cohort``; afterwards a
    round stages only a ``CohortPlan`` and the jitted round gathers its
    batches on device.  Sample row ``pad_index`` (== ``x.shape[1] - 1``) is
    all-zero for every client, as are any dummy client rows added to make
    the row axis divide a mesh's data axis.
    """

    x: Any                   # jax.Array (rows, max_n + 1, *features)
    y: Any                   # jax.Array (rows, max_n + 1)
    rows: dict[int, int]     # client_id -> row
    nbytes: int              # one-time host->device upload size
    _sources: dict[int, Any] = dataclasses.field(default_factory=dict, repr=False)

    @property
    def pad_index(self) -> int:
        return self.x.shape[1] - 1

    @property
    def num_rows(self) -> int:
        return self.x.shape[0]

    def row_of(self, client: ClientDataset) -> int:
        try:
            return self.rows[client.client_id]
        except KeyError:
            raise KeyError(
                f"client {client.client_id} is not part of this device cohort; "
                "attach the full federation before training"
            ) from None

    def owns(self, client: ClientDataset) -> bool:
        """True iff this resident copy was built from exactly this dataset."""
        return self._sources.get(client.client_id) is client.train


def build_device_cohort(
    clients: Sequence[ClientDataset], mesh: Any = None
) -> DeviceCohort:
    """Pad and upload every client's train arrays once.

    The sample axis is padded to ``max_n + 1`` so index ``max_n`` is an
    all-zero row shared by every client — the target of every padding slot
    in a ``CohortPlan``.  With a ``mesh`` carrying a ``"data"`` axis the
    row axis is padded to the axis size with all-zero dummy rows and the
    arrays are sharded over it (one ``device_put`` for the whole pytree).
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    if not clients:
        raise ValueError("empty cohort")
    feat = clients[0].train.x.shape[1:]
    x_dtype = clients[0].train.x.dtype
    y_dtype = clients[0].train.y.dtype
    max_n = max(c.n_train for c in clients)
    shards = 1
    if mesh is not None and "data" in getattr(mesh, "axis_names", ()):
        shards = int(mesh.shape["data"])
    num_rows = len(clients) + (-len(clients) % shards)

    hx = np.zeros((num_rows, max_n + 1, *feat), dtype=x_dtype)
    hy = np.zeros((num_rows, max_n + 1), dtype=y_dtype)
    rows: dict[int, int] = {}
    sources: dict[int, Any] = {}
    for r, client in enumerate(clients):
        if client.train.x.shape[1:] != feat:
            raise ValueError("all cohort clients must share a feature shape")
        n = client.n_train
        hx[r, :n] = client.train.x
        hy[r, :n] = client.train.y
        rows[client.client_id] = r
        sources[client.client_id] = client.train

    if shards > 1:
        sharding = NamedSharding(mesh, P("data"))
        dx, dy = jax.device_put((hx, hy), sharding)
    else:
        dx, dy = jax.device_put((hx, hy))
    return DeviceCohort(
        x=dx, y=dy, rows=rows, nbytes=hx.nbytes + hy.nbytes, _sources=sources,
    )


def build_cohort_plan(
    sizes: Sequence[int],
    batch_size: int,
    local_epochs: int,
    rng: np.random.Generator,
    steps_per_epoch: int | None = None,
    client_rows: Sequence[int] | None = None,
    pad_index: int | None = None,
) -> CohortPlan:
    """The index-plan twin of ``build_cohort_schedule``.

    Consumes ``rng`` in exactly the schedule builder's order (client-major,
    one ``rng.permutation(n_c)`` per epoch), so the two paths are fed
    bit-identical shuffles and can be swapped round for round.  Slots the
    schedule would zero-pad (batch tails, dummy steps) point at
    ``pad_index`` — the device cohort's shared all-zero row.
    """
    sizes = [int(n) for n in sizes]
    if not sizes:
        raise ValueError("empty cohort")
    spe = steps_per_epoch or cohort_steps_per_epoch(sizes, batch_size)
    total = spe * local_epochs
    n_clients = len(sizes)
    if pad_index is None:
        pad_index = max(sizes)
    if pad_index < max(sizes):
        raise ValueError(
            f"pad_index={pad_index} must be >= the largest client size {max(sizes)}"
        )

    sample_idx = np.full((n_clients, total, batch_size), pad_index, dtype=np.int32)
    step_valid = np.zeros((n_clients, total), dtype=bool)
    for c, n in enumerate(sizes):
        steps = -(-n // batch_size)
        if steps > spe:
            raise ValueError(f"client {c} needs more than steps_per_epoch={spe} batches")
        for epoch in range(local_epochs):
            perm = rng.permutation(n)
            t = epoch * spe
            for s in range(steps):
                sel = perm[s * batch_size : (s + 1) * batch_size]
                sample_idx[c, t + s, : len(sel)] = sel
                step_valid[c, t + s] = True

    if client_rows is None:
        client_rows = range(n_clients)
    return CohortPlan(
        sample_idx=sample_idx,
        step_valid=step_valid,
        client_rows=np.asarray(list(client_rows), dtype=np.int32),
        weights=np.asarray(sizes, dtype=np.float32),
        pad_index=pad_index,
        steps_per_epoch=spe,
        local_epochs=local_epochs,
    )


def pad_cohort_plan(plan: CohortPlan, multiple: int) -> CohortPlan:
    """Pad the client axis with weight-0 dummy clients to a multiple.

    The plan twin of ``pad_cohort_schedule``: dummy clients point every
    slot at the pad row (so they gather all-zero batches with an all-zero
    mask), have no valid steps, zero weight, and borrow row 0 — every one
    of their steps is a masked no-op, so they change only the array shape.
    """
    if multiple <= 1:
        return plan
    pad = -plan.num_clients % multiple
    if pad == 0:
        return plan
    return CohortPlan(
        sample_idx=np.concatenate(
            [
                plan.sample_idx,
                np.full((pad, *plan.sample_idx.shape[1:]), plan.pad_index, np.int32),
            ]
        ),
        step_valid=np.concatenate(
            [plan.step_valid, np.zeros((pad, plan.total_steps), dtype=bool)]
        ),
        client_rows=np.concatenate([plan.client_rows, np.zeros(pad, np.int32)]),
        weights=np.concatenate([plan.weights, np.zeros(pad, np.float32)]),
        pad_index=plan.pad_index,
        steps_per_epoch=plan.steps_per_epoch,
        local_epochs=plan.local_epochs,
    )
