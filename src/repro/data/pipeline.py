"""Batching / client-dataset plumbing shared by central and federated training."""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core.histogram import LOS_BIN_EDGES, target_histogram
from repro.core.recruitment import ClientStats
from repro.data.synth_eicu import Cohort


@dataclasses.dataclass
class ArrayDataset:
    """In-memory (x, y) pair with shuffled minibatch iteration."""

    x: np.ndarray
    y: np.ndarray

    def __post_init__(self) -> None:
        assert len(self.x) == len(self.y)

    def __len__(self) -> int:
        return len(self.y)

    def batches(
        self, batch_size: int, rng: np.random.Generator, drop_remainder: bool = False
    ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        idx = rng.permutation(len(self))
        stop = (len(self) // batch_size) * batch_size if drop_remainder else len(self)
        for start in range(0, stop, batch_size):
            sel = idx[start : start + batch_size]
            if drop_remainder and len(sel) < batch_size:
                return
            yield self.x[sel], self.y[sel]

    def padded_batches(
        self, batch_size: int, rng: np.random.Generator
    ) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Fixed-shape batches (pad the tail) -> (x, y, valid_mask).

        Fixed shapes avoid jit recompilation per tail batch.
        """
        for xb, yb in self.batches(batch_size, rng):
            k = len(yb)
            if k < batch_size:
                pad = batch_size - k
                xb = np.concatenate([xb, np.zeros((pad, *xb.shape[1:]), xb.dtype)])
                yb = np.concatenate([yb, np.zeros((pad,), yb.dtype)])
            mask = np.zeros(batch_size, dtype=np.float32)
            mask[:k] = 1.0
            yield xb, yb, mask


@dataclasses.dataclass
class ClientDataset:
    """One hospital's local data (train + val splits)."""

    client_id: int
    train: ArrayDataset
    val: ArrayDataset

    @property
    def n_train(self) -> int:
        return len(self.train)

    def stats(self, edges=LOS_BIN_EDGES) -> ClientStats:
        """The recruitment disclosure tuple (P_co, n_c) — nothing else leaves."""
        return ClientStats(
            client_id=self.client_id,
            counts=target_histogram(self.train.y, edges),
            n=len(self.train),
        )


def build_client_datasets(cohort: Cohort, min_train: int = 2) -> list[ClientDataset]:
    """Split the cohort by originating hospital into per-client datasets.

    Hospitals whose local train split is degenerate (< min_train samples)
    are dropped, mirroring the paper's 208 -> 189 hospital preprocessing cut.
    """
    fused = cohort.fused_features()
    clients: list[ClientDataset] = []
    for h in range(cohort.num_hospitals):
        m_train = (cohort.hospital_id == h) & (cohort.split == Cohort.TRAIN)
        m_val = (cohort.hospital_id == h) & (cohort.split == Cohort.VAL)
        if int(m_train.sum()) < min_train:
            continue
        clients.append(
            ClientDataset(
                client_id=h,
                train=ArrayDataset(fused[m_train], cohort.y[m_train]),
                val=ArrayDataset(fused[m_val], cohort.y[m_val]),
            )
        )
    return clients


def global_dataset(cohort: Cohort, split: int) -> ArrayDataset:
    m = cohort.mask(split)
    return ArrayDataset(cohort.fused_features()[m], cohort.y[m])


def lm_token_batch(
    rng: np.random.Generator, batch: int, seq_len: int, vocab_size: int
) -> dict[str, np.ndarray]:
    """Synthetic LM batch for the assigned language-model architectures."""
    tokens = rng.integers(0, vocab_size, size=(batch, seq_len + 1), dtype=np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
