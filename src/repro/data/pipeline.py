"""Batching / client-dataset plumbing shared by central and federated training."""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

from repro.core.histogram import LOS_BIN_EDGES, target_histogram
from repro.core.recruitment import ClientStats
from repro.data.synth_eicu import Cohort


@dataclasses.dataclass
class ArrayDataset:
    """In-memory (x, y) pair with shuffled minibatch iteration."""

    x: np.ndarray
    y: np.ndarray

    def __post_init__(self) -> None:
        assert len(self.x) == len(self.y)

    def __len__(self) -> int:
        return len(self.y)

    def batches(
        self, batch_size: int, rng: np.random.Generator, drop_remainder: bool = False
    ) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        idx = rng.permutation(len(self))
        stop = (len(self) // batch_size) * batch_size if drop_remainder else len(self)
        for start in range(0, stop, batch_size):
            sel = idx[start : start + batch_size]
            if drop_remainder and len(sel) < batch_size:
                return
            yield self.x[sel], self.y[sel]

    def padded_batches(
        self, batch_size: int, rng: np.random.Generator
    ) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Fixed-shape batches (pad the tail) -> (x, y, valid_mask).

        Fixed shapes avoid jit recompilation per tail batch.
        """
        for xb, yb in self.batches(batch_size, rng):
            k = len(yb)
            if k < batch_size:
                pad = batch_size - k
                xb = np.concatenate([xb, np.zeros((pad, *xb.shape[1:]), xb.dtype)])
                yb = np.concatenate([yb, np.zeros((pad,), yb.dtype)])
            mask = np.zeros(batch_size, dtype=np.float32)
            mask[:k] = 1.0
            yield xb, yb, mask


@dataclasses.dataclass(frozen=True)
class CohortSchedule:
    """A fixed-shape batch plan for one federated round across a client cohort.

    Every client's epoch is padded to ``steps_per_epoch`` with dummy batches
    whose ``step_valid`` flag is False (and whose example mask is all-zero),
    so the whole cohort shares one static ``(clients, steps, batch, ...)``
    shape and a single compilation serves any participant mix.
    """

    x: np.ndarray           # (C, T, B, *feature_dims)
    y: np.ndarray           # (C, T, B)
    mask: np.ndarray        # (C, T, B) float32 per-example validity
    step_valid: np.ndarray  # (C, T) bool — False on dummy padding steps
    weights: np.ndarray     # (C,) float32 local sample counts n_c
    steps_per_epoch: int
    local_epochs: int

    @property
    def num_clients(self) -> int:
        return self.x.shape[0]

    @property
    def total_steps(self) -> int:
        return self.x.shape[1]

    @property
    def real_steps(self) -> int:
        return int(self.step_valid.sum())


def local_round_steps(n: int, batch_size: int, local_epochs: int) -> int:
    """Real local steps one client runs per round: ceil(n / B) * epochs.

    The single source of truth for step accounting — both engines report
    totals through this so their ``total_local_steps`` always agree.
    """
    return -(-int(n) // batch_size) * local_epochs


def cohort_steps_per_epoch(sizes: Sequence[int], batch_size: int) -> int:
    """Common per-epoch step count: the slowest client's ceil(n_c / B)."""
    if not sizes:
        raise ValueError("empty cohort")
    return max(local_round_steps(n, batch_size, 1) for n in sizes)


def build_cohort_schedule(
    datasets: Sequence[ArrayDataset],
    batch_size: int,
    local_epochs: int,
    rng: np.random.Generator,
    steps_per_epoch: int | None = None,
) -> CohortSchedule:
    """Stack every client's shuffled, padded epoch batches into one array.

    Consumes ``rng`` in exactly the order the sequential engine does
    (client-major, one permutation per epoch), so a vectorized round is
    bit-for-bit fed the same batches as the sequential reference.
    """
    if not datasets:
        raise ValueError("empty cohort")
    spe = steps_per_epoch or cohort_steps_per_epoch([len(d) for d in datasets], batch_size)
    total = spe * local_epochs
    feat = datasets[0].x.shape[1:]
    n_clients = len(datasets)

    x = np.zeros((n_clients, total, batch_size, *feat), dtype=datasets[0].x.dtype)
    y = np.zeros((n_clients, total, batch_size), dtype=datasets[0].y.dtype)
    mask = np.zeros((n_clients, total, batch_size), dtype=np.float32)
    step_valid = np.zeros((n_clients, total), dtype=bool)

    for c, dataset in enumerate(datasets):
        if dataset.x.shape[1:] != feat:
            raise ValueError("all cohort clients must share a feature shape")
        for epoch in range(local_epochs):
            t = epoch * spe
            for xb, yb, mb in dataset.padded_batches(batch_size, rng):
                if t >= (epoch + 1) * spe:
                    raise ValueError(
                        f"client {c} produced more than steps_per_epoch={spe} batches"
                    )
                x[c, t], y[c, t], mask[c, t] = xb, yb, mb
                step_valid[c, t] = True
                t += 1
            # remaining slots of this epoch stay dummy (zeros, step_valid False)

    return CohortSchedule(
        x=x,
        y=y,
        mask=mask,
        step_valid=step_valid,
        weights=np.asarray([len(d) for d in datasets], dtype=np.float32),
        steps_per_epoch=spe,
        local_epochs=local_epochs,
    )


def pad_cohort_schedule(sched: CohortSchedule, multiple: int) -> CohortSchedule:
    """Pad the client axis with weight-0 dummy clients to a multiple.

    The shard_map path requires the client axis to divide the mesh's data
    axis; dummy clients have every step masked invalid (exact no-ops) and
    zero aggregation weight, so they change nothing but the array shape.
    """
    if multiple <= 1:
        return sched
    pad = -sched.num_clients % multiple
    if pad == 0:
        return sched

    def pad_clients(a: np.ndarray) -> np.ndarray:
        return np.concatenate([a, np.zeros((pad, *a.shape[1:]), dtype=a.dtype)])

    return CohortSchedule(
        x=pad_clients(sched.x),
        y=pad_clients(sched.y),
        mask=pad_clients(sched.mask),
        step_valid=pad_clients(sched.step_valid),
        weights=pad_clients(sched.weights),
        steps_per_epoch=sched.steps_per_epoch,
        local_epochs=sched.local_epochs,
    )


@dataclasses.dataclass
class ClientDataset:
    """One hospital's local data (train + val splits)."""

    client_id: int
    train: ArrayDataset
    val: ArrayDataset

    @property
    def n_train(self) -> int:
        return len(self.train)

    def stats(self, edges=LOS_BIN_EDGES) -> ClientStats:
        """The recruitment disclosure tuple (P_co, n_c) — nothing else leaves."""
        return ClientStats(
            client_id=self.client_id,
            counts=target_histogram(self.train.y, edges),
            n=len(self.train),
        )


def build_client_datasets(cohort: Cohort, min_train: int = 2) -> list[ClientDataset]:
    """Split the cohort by originating hospital into per-client datasets.

    Hospitals whose local train split is degenerate (< min_train samples)
    are dropped, mirroring the paper's 208 -> 189 hospital preprocessing cut.
    """
    fused = cohort.fused_features()
    clients: list[ClientDataset] = []
    for h in range(cohort.num_hospitals):
        m_train = (cohort.hospital_id == h) & (cohort.split == Cohort.TRAIN)
        m_val = (cohort.hospital_id == h) & (cohort.split == Cohort.VAL)
        if int(m_train.sum()) < min_train:
            continue
        clients.append(
            ClientDataset(
                client_id=h,
                train=ArrayDataset(fused[m_train], cohort.y[m_train]),
                val=ArrayDataset(fused[m_val], cohort.y[m_val]),
            )
        )
    return clients


def global_dataset(cohort: Cohort, split: int) -> ArrayDataset:
    m = cohort.mask(split)
    return ArrayDataset(cohort.fused_features()[m], cohort.y[m])


def lm_token_batch(
    rng: np.random.Generator, batch: int, seq_len: int, vocab_size: int
) -> dict[str, np.ndarray]:
    """Synthetic LM batch for the assigned language-model architectures."""
    tokens = rng.integers(0, vocab_size, size=(batch, seq_len + 1), dtype=np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
