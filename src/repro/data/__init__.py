from repro.data.device_cohort import (
    CohortPlan,
    DeviceCohort,
    build_cohort_plan,
    build_device_cohort,
    pad_cohort_plan,
)
from repro.data.pipeline import (
    ArrayDataset,
    ClientDataset,
    build_client_datasets,
    global_dataset,
    lm_token_batch,
)
from repro.data.synth_eicu import Cohort, CohortConfig, generate_cohort

__all__ = [
    "ArrayDataset",
    "ClientDataset",
    "CohortPlan",
    "DeviceCohort",
    "build_client_datasets",
    "build_cohort_plan",
    "build_device_cohort",
    "pad_cohort_plan",
    "global_dataset",
    "lm_token_batch",
    "Cohort",
    "CohortConfig",
    "generate_cohort",
]
