from repro.data.pipeline import (
    ArrayDataset,
    ClientDataset,
    build_client_datasets,
    global_dataset,
    lm_token_batch,
)
from repro.data.synth_eicu import Cohort, CohortConfig, generate_cohort

__all__ = [
    "ArrayDataset",
    "ClientDataset",
    "build_client_datasets",
    "global_dataset",
    "lm_token_batch",
    "Cohort",
    "CohortConfig",
    "generate_cohort",
]
