"""Synthetic eICU-like cohort generator.

The real eICU Collaborative Research Database is PhysioNet-credential-gated
and unavailable offline (repro band 2 — data gate).  This module simulates a
cohort that matches the *published statistics* of the paper's preprocessed
data (Table 2) and — critically for the recruitment technique — its
*non-IID multi-hospital structure*:

  * 189 hospitals (clients) after preprocessing, 89,127 stays total;
  * power-law hospital sizes (a few large academic centers, many small ones);
  * global LoS ~ lognormal with mean 3.69 days / median 2.27 days;
  * per-hospital LoS distribution *shift and scale* (case-mix heterogeneity),
    so local target histograms genuinely diverge from the global one;
  * 38 features (20 temporal x 24 hourly steps + 18 static), generated from a
    latent severity variable so LoS is learnable from the features;
  * train / val / test = 62,375 / 13,376 / 13,376 split at the *patient*
    level across all hospitals (test set contains patients from hospitals
    that may not be recruited, matching the paper's evaluation protocol).

Everything is deterministic in the seed.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# --- published cohort constants (paper Table 2) ---------------------------
NUM_HOSPITALS = 189
TOTAL_STAYS = 89_127
TRAIN_FRACTION = 62_375 / TOTAL_STAYS
VAL_FRACTION = 13_376 / TOTAL_STAYS
NUM_TEMPORAL = 20
NUM_STATIC = 18
NUM_HOURS = 24
# lognormal(mu0, sigma0) gives median exp(mu0)=2.27d, mean exp(mu0+s^2/2)=3.69d
LOS_MU0 = float(np.log(2.27))
LOS_SIGMA0 = float(np.sqrt(2.0 * np.log(3.69 / 2.27)))


@dataclasses.dataclass(frozen=True)
class CohortConfig:
    num_hospitals: int = NUM_HOSPITALS
    total_stays: int = TOTAL_STAYS
    num_temporal: int = NUM_TEMPORAL
    num_static: int = NUM_STATIC
    num_hours: int = NUM_HOURS
    # non-IID strength: stddev of per-hospital lognormal-mu shift and the
    # range of the sigma scaling.  0 shift/1 scale = IID hospitals.
    hospital_mu_shift: float = 0.35
    hospital_sigma_scale: tuple[float, float] = (0.75, 1.30)
    min_hospital_size: int = 25
    size_power: float = 1.3  # pareto tail exponent for hospital sizes
    # Observation / severity noise calibrated so a well-trained central GRU
    # lands near the paper's Table 4 (MAE ~2.2, MSLE ~0.33): first-24h ICU
    # features only weakly predict LoS in the real cohort, and the synthetic
    # cohort must reproduce that difficulty, not just the marginals.
    noise: float = 1.0       # observation noise on features
    severity_noise: float = 1.05  # latent severity decoupling from true LoS
    # per-hospital feature-noise multiplier range: (1.0, 1.0) = homogeneous
    # data quality; widen (e.g. (0.7, 2.5)) to model sites with poor charting
    # whose updates actively hurt the federation (the recruitment target).
    hospital_noise_scale: tuple[float, float] = (1.0, 1.0)
    # "global" = one patient-level permutation across all hospitals (the
    # paper's protocol); "stratified" = the same fractions applied within
    # every hospital, so local split sizes carry no sampling noise (the
    # standard multi-site alternative — and what keeps the vectorized
    # engine's shared step axis tight at paper scale).
    split_mode: str = "global"
    seed: int = 0

    def scaled(self, factor: float) -> "CohortConfig":
        """Smaller cohort for tests: scale total stays, keep structure."""
        return dataclasses.replace(
            self,
            total_stays=max(int(self.total_stays * factor), self.num_hospitals * 4),
            min_hospital_size=max(2, int(self.min_hospital_size * factor)),
        )


@dataclasses.dataclass
class Cohort:
    """Materialized synthetic cohort.

    ``x_temporal``: (N, 24, 20) float32 — hourly vitals/labs.
    ``x_static``:   (N, 18) float32 — demographics, admission info.
    ``y``:          (N,) float32 — LoS in fractional days.
    ``hospital_id``: (N,) int32 — originating hospital in [0, H).
    ``split``:      (N,) int8 — 0 train / 1 val / 2 test.
    """

    x_temporal: np.ndarray
    x_static: np.ndarray
    y: np.ndarray
    hospital_id: np.ndarray
    split: np.ndarray
    config: CohortConfig

    TRAIN, VAL, TEST = 0, 1, 2

    @property
    def num_hospitals(self) -> int:
        return self.config.num_hospitals

    def mask(self, split: int) -> np.ndarray:
        return self.split == split

    def fused_features(self) -> np.ndarray:
        """Temporal fused with broadcast static features: (N, 24, 38).

        Cached: at full paper scale this is a ~330 MB materialization, and
        drivers like ``run_paper_scale`` walk the same cohort through many
        settings/engines — building it once instead of per run_setting call.
        """
        cached = getattr(self, "_fused", None)
        if cached is None:
            static_tiled = np.repeat(self.x_static[:, None, :], self.x_temporal.shape[1], axis=1)
            cached = np.concatenate([self.x_temporal, static_tiled], axis=-1).astype(np.float32)
            self._fused = cached
        return cached

    def client_arrays(self, hospital: int, split: int) -> tuple[np.ndarray, np.ndarray]:
        """(fused features, y) for one hospital and split."""
        m = (self.hospital_id == hospital) & (self.split == split)
        return self.fused_features()[m], self.y[m]

    def client_sizes(self, split: int = TRAIN) -> np.ndarray:
        sizes = np.zeros(self.num_hospitals, dtype=np.int64)
        ids, counts = np.unique(self.hospital_id[self.split == split], return_counts=True)
        sizes[ids] = counts
        return sizes


def _hospital_sizes(rng: np.random.Generator, cfg: CohortConfig) -> np.ndarray:
    """Power-law sizes summing exactly to total_stays, each >= min size."""
    raw = rng.pareto(cfg.size_power, size=cfg.num_hospitals) + 1.0
    budget = cfg.total_stays - cfg.min_hospital_size * cfg.num_hospitals
    if budget < 0:
        raise ValueError("total_stays too small for min_hospital_size * num_hospitals")
    extra = np.floor(raw / raw.sum() * budget).astype(np.int64)
    sizes = extra + cfg.min_hospital_size
    # distribute the rounding remainder to the largest hospitals
    remainder = cfg.total_stays - int(sizes.sum())
    order = np.argsort(-sizes)
    sizes[order[:remainder]] += 1
    assert sizes.sum() == cfg.total_stays
    return sizes


def generate_cohort(config: CohortConfig | None = None, seed: int | None = None) -> Cohort:
    cfg = config or CohortConfig()
    if seed is not None:
        cfg = dataclasses.replace(cfg, seed=seed)
    rng = np.random.default_rng(cfg.seed)

    sizes = _hospital_sizes(rng, cfg)
    hospital_id = np.repeat(np.arange(cfg.num_hospitals, dtype=np.int32), sizes)
    n = cfg.total_stays

    # --- per-hospital non-IID LoS ------------------------------------------
    mu_shift = rng.normal(0.0, cfg.hospital_mu_shift, size=cfg.num_hospitals)
    sig_scale = rng.uniform(*cfg.hospital_sigma_scale, size=cfg.num_hospitals)
    mu_h = LOS_MU0 + mu_shift
    sigma_h = LOS_SIGMA0 * sig_scale
    log_los = rng.normal(mu_h[hospital_id], sigma_h[hospital_id])
    y = np.exp(log_los).astype(np.float32)
    y = np.clip(y, 2.0 / 24.0, 120.0)  # at least 2h, at most 120d in ICU

    # --- latent severity drives the features -------------------------------
    # severity = standardized log-LoS within the global distribution + noise,
    # so features carry real signal about the target.
    severity = (np.log(y) - LOS_MU0) / LOS_SIGMA0
    severity = severity + rng.normal(0.0, cfg.severity_noise, size=n)

    hosp_offset_t = rng.normal(0.0, 0.3, size=(cfg.num_hospitals, cfg.num_temporal))
    hosp_offset_s = rng.normal(0.0, 0.3, size=(cfg.num_hospitals, cfg.num_static))
    hosp_noise = rng.uniform(*cfg.hospital_noise_scale, size=cfg.num_hospitals)

    # temporal: per-feature loading on severity, hourly trend + diurnal tone
    load_t = rng.normal(0.0, 1.0, size=cfg.num_temporal)
    trend = rng.normal(0.0, 0.15, size=cfg.num_temporal)
    hours = np.arange(cfg.num_hours, dtype=np.float32)
    base = severity[:, None] * load_t[None, :]                       # (N, F_t)
    x_temporal = (
        base[:, None, :]
        + trend[None, None, :] * (hours[None, :, None] / cfg.num_hours) * severity[:, None, None]
        + 0.10 * np.sin(2 * np.pi * hours[None, :, None] / 24.0)
        + hosp_offset_t[hospital_id][:, None, :]
        + hosp_noise[hospital_id][:, None, None]
        * rng.normal(0.0, cfg.noise, size=(n, cfg.num_hours, cfg.num_temporal))
    ).astype(np.float32)

    # static: age/gender/diagnosis-like one-hot-ish blocks + severity loading
    load_s = rng.normal(0.0, 0.8, size=cfg.num_static)
    x_static = (
        severity[:, None] * load_s[None, :]
        + hosp_offset_s[hospital_id]
        + hosp_noise[hospital_id][:, None]
        * rng.normal(0.0, cfg.noise, size=(n, cfg.num_static))
    ).astype(np.float32)
    # a few genuinely categorical static columns (one-hot over 4 "units")
    unit = rng.integers(0, 4, size=n)
    for k in range(4):
        x_static[:, k] = (unit == k).astype(np.float32)

    # --- splits ------------------------------------------------------------
    split = np.full(n, Cohort.TEST, dtype=np.int8)
    if cfg.split_mode == "stratified":
        # the same fractions within every hospital: per-client split sizes
        # are deterministic in the hospital size, no cross-site noise
        for h in range(cfg.num_hospitals):
            idx = rng.permutation(np.flatnonzero(hospital_id == h))
            k_train = int(round(TRAIN_FRACTION * len(idx)))
            k_val = int(round(VAL_FRACTION * len(idx)))
            split[idx[:k_train]] = Cohort.TRAIN
            split[idx[k_train : k_train + k_val]] = Cohort.VAL
    elif cfg.split_mode == "global":
        perm = rng.permutation(n)
        n_train = int(round(TRAIN_FRACTION * n))
        n_val = int(round(VAL_FRACTION * n))
        split[perm[:n_train]] = Cohort.TRAIN
        split[perm[n_train : n_train + n_val]] = Cohort.VAL
    else:
        raise ValueError(f"unknown split_mode {cfg.split_mode!r}")

    return Cohort(
        x_temporal=x_temporal,
        x_static=x_static,
        y=y,
        hospital_id=hospital_id,
        split=split,
        config=cfg,
    )
