"""Beyond-paper example: the recruitment technique is model-agnostic.

Federated fine-tuning of a *reduced* smollm-135m across synthetic hospital
text shards: each client's disclosure is a TOKEN histogram (10 vocabulary
buckets) + sample size — exactly the paper's (P_co, n_c) tuple, applied to a
language model instead of the LoS GRU.  Recruitment then gates which
hospitals join the federation, and FedAvg aggregates transformer weights.

    PYTHONPATH=src python examples/federated_lm.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.histogram import token_histogram
from repro.core.recruitment import BALANCED, ClientStats, recruit
from repro.federated.fedavg import aggregate
from repro.launch.steps import make_train_step
from repro.models.zoo import Model
from repro.optim.adamw import AdamW

NUM_CLIENTS = 12
SEQ, BATCH = 64, 4
ROUNDS, LOCAL_STEPS = 3, 5


def make_client_corpus(rng, vocab, skew: float):
    """Non-IID token distributions: each hospital's notes favor a band of the
    vocabulary (specialty jargon); skew controls divergence."""
    center = rng.uniform(0, vocab)
    width = vocab * (1.0 - 0.8 * skew)
    n_samples = int(rng.integers(40, 400))
    toks = (rng.normal(center, width, size=(n_samples, SEQ + 1)) % vocab).astype(np.int32)
    return toks


def main() -> None:
    cfg = get_config("smollm-135m").reduced()
    model = Model(cfg, remat=False)
    optimizer = AdamW(learning_rate=1e-3)
    rng = np.random.default_rng(0)

    corpora = [make_client_corpus(rng, cfg.vocab_size, skew=rng.uniform(0, 1)) for _ in range(NUM_CLIENTS)]

    # recruitment on token histograms — the paper's disclosure, LM flavor
    stats = [
        ClientStats(client_id=i, counts=token_histogram(c[:, 1:], cfg.vocab_size), n=len(c))
        for i, c in enumerate(corpora)
    ]
    res = recruit(stats, dataclasses.replace(BALANCED, gamma_th=0.3))
    print(f"recruited {res.num_recruited}/{NUM_CLIENTS} hospital text shards: "
          f"{sorted(res.recruited_ids.tolist())}")

    params = model.init(jax.random.key(0))
    step = jax.jit(make_train_step(model, optimizer))

    for rnd in range(ROUNDS):
        client_params, weights = [], []
        for cid in res.recruited_ids:
            corpus = corpora[int(cid)]
            p, opt_state = params, optimizer.init(params)
            losses = []
            for k in range(LOCAL_STEPS):
                idx = rng.integers(0, len(corpus), BATCH)
                toks = corpus[idx]
                batch = {
                    "tokens": jnp.asarray(toks[:, :-1]),
                    "labels": jnp.asarray(toks[:, 1:]),
                }
                p, opt_state, metrics = step(p, opt_state, batch)
                losses.append(float(metrics["loss"]))
            client_params.append(p)
            weights.append(len(corpus))
        params = aggregate(client_params, weights)
        print(f"round {rnd}: mean local loss {np.mean(losses):.4f} "
              f"({len(client_params)} clients aggregated)")

    print("federated LM fine-tuning done — recruitment + FedAvg over a transformer.")


if __name__ == "__main__":
    main()
