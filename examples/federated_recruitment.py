"""End-to-end driver: the paper's full experiment, as the paper's kind
dictates — federated training of the LoS GRU across 189 hospital clients,
with and without client recruitment, several hundred local steps per model.

    PYTHONPATH=src python examples/federated_recruitment.py [--scale 0.3]

Produces the SC-vs-SRC comparison that is the paper's headline claim:
recruited federations match or beat standard FedAvg at a fraction of the
training cost.

Policy API
----------
Every paper setting is a 3-line policy combination for the
``repro.federated.api.Federation`` facade — a recruitment spec, a selection
spec, and an aggregator spec::

    FederationConfig(recruitment="nu-greedy",      # the paper's greedy rule
                     selection="uniform:0.1",      # 10% sampled per round
                     aggregator="fedavg")          # weighted averaging

Built-in registries (``repro.federated.available_policies()``):

* recruitment — ``nu-greedy`` (optionally ``nu-greedy:balanced`` /
  ``nu-greedy:gamma_dv,gamma_sa,gamma_th``), ``random-k:K`` (the
  recruitment control), ``top-n-samples:N``, ``all``
* selection — ``uniform[:frac|count]``, ``round-robin[:frac|count]``
  (deterministic rotation), ``loss-weighted[:frac|count]`` (sample by last
  observed local loss)
* aggregator — ``fedavg``, ``trimmed-mean[:trim]`` (coordinate-wise robust
  mean), ``hierarchical[:regions]`` (two-level FedAvg: regional
  sub-federations psum first — the seed of the multi-pod aggregation tier)

``--recruitment`` / ``--selection`` / ``--aggregator`` below override the
per-setting defaults with any spec; user-defined policies are ~20 lines
(see ``examples/custom_policy.py``).  The legacy ``FederatedServer`` /
``FederatedConfig`` remain as deprecation shims over this facade.

Paper-scale runs
----------------
The full 189-client experiment grid (all five section-6 model settings,
both engines, per-setting round times, the donated-vs-plain buffer memory
probe) is a benchmark mode of its own and writes ``BENCH_paper189.json``:

    PYTHONPATH=src python benchmarks/run.py --mode paper189

To push the cohort's client axis through the multi-device ``shard_map``
path (CI's second matrix leg does this on every PR), force host devices
before jax initializes and ask for the auto data mesh:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
        PYTHONPATH=src python benchmarks/run.py --mode paper189 --mesh-auto

Device-resident staging
-----------------------
By default the federation's client train arrays are uploaded to device
**once** (``--staging resident``); each round then stages only a compact
``(clients, steps, batch)`` int32 index plan — the batch gather happens on
device, inside the jitted round, and chunk k+1's plan is built/uploaded on
a background thread while chunk k trains (disable with ``--no-prefetch``).
``--staging rebuild`` restores the re-materialize-and-re-upload path each
round (PR 2's behavior, kept as the staging reference oracle; both paths
draw the same RNG stream and agree within 1e-5).  The two are compared
head to head by ``python benchmarks/run.py --mode pipeline``, which writes
``BENCH_pipeline.json`` (per-round staged bytes drop ~880x, rounds run
1.6-1.8x faster at 189 clients on CI hardware).

This driver accepts the same engine controls (``--engine``,
``--cohort-chunk``, ``--mesh auto``, ``--no-donate``, ``--staging``,
``--no-prefetch``) plus the policy overrides (``--selection``,
``--aggregator``) for one-off runs.

Async federation & straggler simulation
---------------------------------------
The paper's headline is a *training time* claim, and in a real deployment
the dominant cost is waiting for slow or flaky ICUs — which a synchronous
round barrier can't express.  ``repro.federated.runtime`` adds an
event-driven twin of the facade: a deterministic virtual-clock scheduler
dispatches client tasks under pluggable per-client latency and dropout
models (``latency="constant" | "lognormal:0.5" | "pareto:1.5" | "trace"``,
``dropout="bernoulli:0.1"`` — same registry grammar as the policies), and
buffered aggregators fold completions into new parameter versions with
polynomial staleness-decay weights::

    AsyncFederationConfig(recruitment="nu-greedy",
                          aggregator="fedbuff:16",        # flush every 16 updates
                          latency="pareto:1.2",           # heavy-tailed stragglers
                          dropout=0.05)
    AsyncFederation(cfg, clients, loss_fn, opt).run(params)

``"fedbuff:K"`` is buffered async FedAvg (K = all participants + zero
latency spread reproduces synchronous FedAvg to 1e-5 — the tier-1 parity
gate); ``"hierarchical-async:R"`` promotes the sync ``"hierarchical:R"``
aggregator to stale-tolerant cross-pod combines (regions merge whenever
they finish).  Each task still runs through the unchanged jitted /
donated / shard_map cohort engine — the runtime only reorders which cohort
chunks train against which parameter version.  Flush records carry
``virtual_time`` / ``staleness``, so recruited-vs-all federations compare
on *simulated time-to-target-loss*: see ``examples/async_federation.py``
and ``python benchmarks/run.py --mode async`` (-> ``BENCH_async.json``).
"""

import argparse
import json

from repro.experiments.paper import ExperimentConfig, build_cohort, run_setting


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.3, help="cohort scale (1.0 = 89k stays)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--engine", choices=["vectorized", "sequential"], default="vectorized",
        help="vectorized = whole cohort per round in one jitted vmap",
    )
    ap.add_argument(
        "--cohort-chunk", type=int, default=None,
        help="vectorized engine: clients per vmapped call (bounds memory)",
    )
    ap.add_argument(
        "--mesh", choices=["auto"], default=None,
        help="vectorized engine: shard the client axis over all visible devices",
    )
    ap.add_argument(
        "--no-donate", action="store_true",
        help="vectorized engine: keep round buffers alive (memory diffing)",
    )
    ap.add_argument(
        "--staging", choices=["resident", "rebuild"], default="resident",
        help="resident = client data uploaded once, rounds stage int32 index "
        "plans; rebuild = full schedule re-uploaded every round",
    )
    ap.add_argument(
        "--no-prefetch", action="store_true",
        help="resident staging: build chunk plans inline instead of on the "
        "double-buffering background thread",
    )
    ap.add_argument(
        "--selection", default=None,
        help="override the per-round selection policy spec (e.g. "
        "'round-robin:0.1', 'loss-weighted:0.1'); default derives the "
        "paper's uniform sampling from the setting",
    )
    ap.add_argument(
        "--aggregator", default="fedavg",
        help="aggregation policy spec ('fedavg', 'trimmed-mean:0.1', "
        "'hierarchical:4')",
    )
    args = ap.parse_args()

    # paper-faithful settings, trained on the selected engine
    exp = ExperimentConfig(
        cohort_scale=args.scale,
        engine=args.engine,
        cohort_chunk=args.cohort_chunk,
        mesh=args.mesh,
        donate_buffers=not args.no_donate,
        staging=args.staging,
        prefetch=not args.no_prefetch,
        selection=args.selection,
        aggregator=args.aggregator,
    )
    print(f"engine: {args.engine}")
    cohort = build_cohort(exp, seed=args.seed)
    print(f"cohort: {len(cohort.y):,} stays, {cohort.num_hospitals} hospitals")

    results = {}
    for setting in ("federated-sc", "federated-src"):
        print(f"--- {setting} (15 rounds x 4 local epochs) ---")
        out = run_setting(setting, exp, cohort, seed=args.seed)
        results[setting] = out
        print(
            f"  federation={out['federation_size']} recruited={out['recruited']} "
            f"local_steps={out['local_steps']} tau={out['tau_s']:.1f}s"
        )
        print(f"  metrics: {json.dumps({k: round(v, 4) for k, v in out['metrics'].items()})}")

    sc, src = results["federated-sc"], results["federated-src"]
    speedup = sc["tau_s"] / src["tau_s"]
    print(
        f"\nRecruited federation (SRC): {src['recruited']} of {sc['federation_size']} clients, "
        f"{speedup:.2f}x faster than standard FedAvg (SC), "
        f"MSLE {src['metrics']['msle']:.4f} vs {sc['metrics']['msle']:.4f}"
    )


if __name__ == "__main__":
    main()
