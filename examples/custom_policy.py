"""A user-defined recruitment policy in under 30 lines.

    PYTHONPATH=src python examples/custom_policy.py

The Federation facade treats recruitment / selection / aggregation as
pluggable stages.  This example writes a new ``RecruitmentPolicy`` —
"median-band": recruit only hospitals whose sample size sits within a band
around the cohort median, a crude fairness rule that excludes both tiny,
noisy sites and dominating academic centers — registers it under a spec
name, and trains a federation with it, changing nothing else.
"""

import jax
import numpy as np

from repro.data import CohortConfig, build_client_datasets, generate_cohort
from repro.federated import (
    Federation,
    FederationConfig,
    RecruitmentDecision,
    RecruitmentPolicy,
    register_recruitment,
)
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim import AdamW


# The whole policy: subclass, implement recruit(), return sorted ids.
# Policies see only the disclosure tuples (target histogram, n_c) — never
# raw features — so recruitment stays model-agnostic by construction.
@register_recruitment("median-band")
class MedianBandRecruitment(RecruitmentPolicy):
    """Recruit clients whose n_c lies within ``band``x of the median size."""

    def __init__(self, band: float = 2.0) -> None:
        self.band = float(band)

    def recruit(self, stats, rng):
        sizes = np.array([s.n for s in stats], dtype=np.float64)
        ids = np.array([s.client_id for s in stats], dtype=np.int64)
        median = np.median(sizes)
        keep = (sizes >= median / self.band) & (sizes <= median * self.band)
        if not keep.any():  # degenerate cohort: fall back to everyone
            keep[:] = True
        return RecruitmentDecision(federation_ids=np.sort(ids[keep]))


def main() -> None:
    cohort = generate_cohort(CohortConfig().scaled(0.02), seed=0)
    clients = build_client_datasets(cohort)
    model_cfg = GRUConfig()

    # Registered policies compose by spec string like any built-in; an
    # instance (MedianBandRecruitment(1.5)) would work the same.
    fed_cfg = FederationConfig(
        rounds=2, local_epochs=1, seed=0,
        recruitment="median-band:2.0", selection="uniform:0.5", aggregator="fedavg",
    )
    federation = Federation(
        fed_cfg, clients, make_loss_fn(model_cfg),
        AdamW(learning_rate=5e-3, weight_decay=5e-3),
    )
    out = federation.run(init_gru(jax.random.key(0), model_cfg))
    sizes = {c.client_id: c.n_train for c in clients}
    picked = [sizes[int(i)] for i in out.federation_ids]
    print(
        f"median-band recruited {out.federation_ids.size}/{len(clients)} hospitals "
        f"(sizes {min(picked)}..{max(picked)}, cohort median "
        f"{int(np.median(list(sizes.values())))})"
    )
    for r in out.history:
        print(
            f"  round {r.round_index}: {len(r.participant_ids)} clients, "
            f"loss {r.mean_local_loss:.4f}, {r.bytes_transferred:,} bytes moved"
        )
    print("summary:", out.summary())


if __name__ == "__main__":
    main()
