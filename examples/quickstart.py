"""Quickstart: client recruitment + a small federation in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import BALANCED, recruit
from repro.data import CohortConfig, build_client_datasets, generate_cohort, global_dataset
from repro.federated import Federation, FederationConfig
from repro.metrics import evaluate_predictions
from repro.models.gru import GRUConfig, gru_apply, init_gru, make_loss_fn
from repro.optim import AdamW


def main() -> None:
    # 1. a synthetic multi-hospital ICU cohort (5% of the paper's scale)
    cohort = generate_cohort(CohortConfig().scaled(0.05), seed=0)
    clients = build_client_datasets(cohort)
    print(f"cohort: {len(cohort.y):,} stays across {len(clients)} hospitals")

    # 2. recruitment: each hospital discloses ONLY (target histogram, n_c)
    stats = [c.stats() for c in clients]
    result = recruit(stats, BALANCED)
    print(
        f"recruited {result.num_recruited}/{len(clients)} clients "
        f"(gamma_dv={BALANCED.gamma_dv}, gamma_sa={BALANCED.gamma_sa}, "
        f"gamma_th={BALANCED.gamma_th}; threshold iota={result.iota:.2f})"
    )

    # 3. federated training as a policy combination (Federated-SRC setting):
    #    nu-greedy recruitment + 10% uniform per-round sampling + FedAvg.
    #    Swap any stage by spec string — recruitment="random-k:20",
    #    selection="round-robin:0.1", aggregator="trimmed-mean:0.1", ... —
    #    or pass your own policy instance (see examples/custom_policy.py).
    #    The vectorized engine trains every round participant inside ONE
    #    jitted vmap; client data is uploaded to device once
    #    (staging="resident") and rounds stage only int32 index plans.
    model_cfg = GRUConfig()
    fed_cfg = FederationConfig(
        rounds=5, local_epochs=2, seed=0, engine="vectorized",
        recruitment="nu-greedy", selection="uniform:0.1", aggregator="fedavg",
    )
    print(f"engine: {fed_cfg.engine}")
    federation = Federation(
        fed_cfg,
        clients,
        make_loss_fn(model_cfg),
        AdamW(learning_rate=5e-3, weight_decay=5e-3),
    )
    out = federation.run(
        init_gru(jax.random.key(0), model_cfg),
        progress=lambda r: print(
            f"  round {r.round_index}: {len(r.participant_ids)} clients, "
            f"local loss {r.mean_local_loss:.4f}"
        ),
    )

    # 4. evaluate on held-out patients from ALL hospitals (recruited or not)
    test = global_dataset(cohort, cohort.TEST)
    y_hat = np.asarray(gru_apply(out.params, model_cfg, test.x))
    print("test metrics:", {k: round(v, 4) for k, v in evaluate_predictions(test.y, y_hat).items()})
    print("total wall time:", f"{out.total_wall_time_s:.1f}s")


if __name__ == "__main__":
    main()
