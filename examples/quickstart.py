"""Quickstart: client recruitment + a small federation in ~2 minutes on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import BALANCED, recruit
from repro.data import CohortConfig, build_client_datasets, generate_cohort, global_dataset
from repro.federated import FederatedConfig, FederatedServer
from repro.metrics import evaluate_predictions
from repro.models.gru import GRUConfig, gru_apply, init_gru, make_loss_fn
from repro.optim import AdamW


def main() -> None:
    # 1. a synthetic multi-hospital ICU cohort (5% of the paper's scale)
    cohort = generate_cohort(CohortConfig().scaled(0.05), seed=0)
    clients = build_client_datasets(cohort)
    print(f"cohort: {len(cohort.y):,} stays across {len(clients)} hospitals")

    # 2. recruitment: each hospital discloses ONLY (target histogram, n_c)
    stats = [c.stats() for c in clients]
    result = recruit(stats, BALANCED)
    print(
        f"recruited {result.num_recruited}/{len(clients)} clients "
        f"(gamma_dv={BALANCED.gamma_dv}, gamma_sa={BALANCED.gamma_sa}, "
        f"gamma_th={BALANCED.gamma_th}; threshold iota={result.iota:.2f})"
    )

    # 3. federated training on the recruited subset (Federated-SRC setting).
    #    The vectorized engine trains every round participant inside ONE
    #    jitted vmap; engine="sequential" is the per-client reference loop.
    #    Client data is uploaded to device once (staging="resident") — each
    #    round stages only an int32 index plan and gathers batches on device.
    model_cfg = GRUConfig()
    fed_cfg = FederatedConfig(
        rounds=5, local_epochs=2, participation_fraction=0.1,
        recruitment=BALANCED, seed=0, engine="vectorized",
    )
    print(f"engine: {fed_cfg.engine}")
    server = FederatedServer(
        fed_cfg,
        clients,
        make_loss_fn(model_cfg),
        AdamW(learning_rate=5e-3, weight_decay=5e-3),
    )
    out = server.run(
        init_gru(jax.random.key(0), model_cfg),
        progress=lambda r: print(
            f"  round {r.round_index}: {len(r.participant_ids)} clients, "
            f"local loss {r.mean_local_loss:.4f}"
        ),
    )

    # 4. evaluate on held-out patients from ALL hospitals (recruited or not)
    test = global_dataset(cohort, cohort.TEST)
    y_hat = np.asarray(gru_apply(out.params, model_cfg, test.x))
    print("test metrics:", {k: round(v, 4) for k, v in evaluate_predictions(test.y, y_hat).items()})
    print("total wall time:", f"{out.total_wall_time_s:.1f}s")


if __name__ == "__main__":
    main()
