"""Private federation: in-jit DP-SGD, masked-sum secagg, one attack.

    PYTHONPATH=src python examples/private_federation.py

Three runs on the same small cohort: (1) DP-SGD — per-example clipping
and Gaussian noise inside the jitted round, with the accountant's
cumulative epsilon on every round record; (2) the same round program
aggregated through pairwise-masked fixed-point sums, so the server never
sees a plaintext update; (3) a label-flip attack that plain FedAvg
absorbs into the average but the Krum aggregator discards.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import CohortConfig, build_client_datasets, generate_cohort
from repro.federated import Federation, FederationConfig
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim import AdamW
from repro.privacy import DPConfig, ScenarioConfig, apply_scenario


def main() -> None:
    cohort = generate_cohort(CohortConfig().scaled(0.02), seed=0)
    clients = build_client_datasets(cohort)[:12]
    model_cfg = GRUConfig(dropout=0.0, hidden_dim=8, num_layers=1)
    loss_fn, optimizer = make_loss_fn(model_cfg), AdamW(learning_rate=5e-3)
    params0 = init_gru(jax.random.key(0), model_cfg)

    def run(fed_cfg, scenario=None, opt=optimizer):
        federation = Federation(fed_cfg, clients, loss_fn, opt)
        if scenario is not None:
            apply_scenario(federation, scenario)
        return federation.run(params0)

    # 1. DP-SGD rides the jitted cohort step; epsilon accumulates per round.
    out = run(FederationConfig(
        rounds=3, local_epochs=2, batch_size=16, seed=0,
        privacy=DPConfig(clip_norm=1.0, noise_multiplier=1.1),
    ))
    for record in out.history:
        print(f"  round {record.round_index}: loss {record.mean_local_loss:.4f} "
              f"epsilon {record.epsilon:.2f}")
    print(f"DP-SGD final (epsilon, delta): ({out.summary()['epsilon']:.2f}, 1e-05)")

    # 2. Secure aggregation: the server sums masked fixed-point tensors;
    #    ":0.2" lets each client drop out with p=0.2 (mask recovery path).
    out = run(FederationConfig(
        rounds=3, local_epochs=2, batch_size=16, seed=0,
        aggregator="secagg-fedavg:0.2",
    ))
    print(f"secagg final loss: {out.history[-1].mean_local_loss:.4f}")

    # 3. Adversarial clients: 30% of clients flip their labels.  Krum
    #    scores updates by neighbor distance and discards the attackers.
    #    Evaluate on clean held-out data — reported local losses would be
    #    contaminated by what the attackers claim about their own data.
    val = (jnp.asarray(np.concatenate([np.asarray(c.val.x) for c in clients])),
           jnp.asarray(np.concatenate([np.asarray(c.val.y) for c in clients])),
           None)
    val = (val[0], val[1], jnp.ones(val[1].shape[0], jnp.float32))
    attack = ScenarioConfig(attack="label-flip", fraction=0.3, seed=5)
    hot = AdamW(learning_rate=5e-2)  # enough rounds x lr for attacks to bite
    for aggregator in ("fedavg", "krum:4"):
        cfg = FederationConfig(rounds=6, local_epochs=3, batch_size=16,
                               seed=0, aggregator=aggregator)
        clean = loss_fn(run(cfg, opt=hot).params, val, jax.random.key(9))
        bad = loss_fn(run(cfg, attack, opt=hot).params, val, jax.random.key(9))
        print(f"{aggregator}: clean val {clean:.4f} vs attacked {bad:.4f}")


if __name__ == "__main__":
    main()
