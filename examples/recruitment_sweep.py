"""Recruitment-parameter study (paper section 6.2 / Fig. 2).

Sweeps gamma_th (number of recruited clients) and compares the balanced,
quality-greedy, and data-greedy strategies.

    PYTHONPATH=src python examples/recruitment_sweep.py [--scale 0.1]
"""

import argparse
import dataclasses

from repro.core import BALANCED, DATA_GREEDY, QUALITY_GREEDY, recruit, recruitment_curve
from repro.data import CohortConfig, build_client_datasets, generate_cohort
from repro.experiments.paper import ExperimentConfig, build_cohort, run_setting


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--train", action="store_true", help="also train at each gamma_th")
    args = ap.parse_args()

    cohort = generate_cohort(CohortConfig().scaled(args.scale), seed=0)
    stats = [c.stats() for c in build_client_datasets(cohort)]

    print("gamma_th -> clients recruited (balanced strategy)")
    for gth, n in recruitment_curve(stats, BALANCED, [0.05, 0.1, 0.2, 0.4, 0.7, 1.0]):
        bar = "#" * max(1, n // 4)
        print(f"  {gth:4.2f}: {n:4d} {bar}")

    print("\nstrategy comparison at gamma_th=0.1:")
    for name, cfg in (("balanced", BALANCED), ("quality-greedy", QUALITY_GREEDY), ("data-greedy", DATA_GREEDY)):
        res = recruit(stats, cfg)
        sizes = [s.n for s in stats if res.is_recruited(s.client_id)]
        print(
            f"  {name:15s}: {res.num_recruited:3d} clients, "
            f"median local n={sorted(sizes)[len(sizes)//2]}"
        )

    if args.train:
        exp = ExperimentConfig(cohort_scale=args.scale, rounds=5, local_epochs=2)
        cohort_t = build_cohort(exp, seed=0)
        print("\ntraining at each gamma_th (federated-src):")
        for gth in (0.05, 0.1, 0.3, 0.7):
            e = dataclasses.replace(exp, gamma_th=gth)
            out = run_setting("federated-src", e, cohort_t, seed=0)
            print(
                f"  gamma_th={gth:4.2f}: recruited={out['recruited']:3d} "
                f"msle={out['metrics']['msle']:.4f} mae={out['metrics']['mae']:.3f} "
                f"tau={out['tau_s']:.1f}s"
            )


if __name__ == "__main__":
    main()
