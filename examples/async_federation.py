"""Async federation demo: stragglers, staleness, and the recruitment claim.

    PYTHONPATH=src python examples/async_federation.py [--scale 0.05]

The synchronous engines measure per-round device time; this demo measures
what the paper actually claims — *training time* in a deployment where
some ICUs are slow and some drop out.  It runs the event-driven
``AsyncFederation`` (``repro.federated.runtime``) twice under a
heavy-tailed straggler latency model — once with every hospital in the
federation, once with only the nu-greedy recruited subset — and compares
the simulated virtual-clock time each needs to reach a shared target loss.

Things to try:

* ``--latency pareto:1.2`` (fatter straggler tail), ``--latency trace``
  (compute time tracks local data size — the big hospitals become the slow
  hospitals), ``--latency constant`` (no spread: fedbuff with a full
  buffer degenerates to synchronous FedAvg, the tier-1 parity gate).
* ``--aggregator hierarchical-async:4`` — regional sub-federations whose
  cross-pod combines tolerate stale global params (ROADMAP scale step (b)
  in simulation).
* ``--dropout 0.2`` — every dispatch fails with probability 0.2; dropped
  clients retry after their latency elapses.
"""

import argparse

import jax

from repro.data.pipeline import build_client_datasets
from repro.data.synth_eicu import CohortConfig, generate_cohort
from repro.experiments.paper import shared_time_to_target
from repro.federated.runtime import AsyncFederation, AsyncFederationConfig
from repro.models.gru import GRUConfig, init_gru, make_loss_fn
from repro.optim.adamw import AdamW


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05, help="cohort scale (1.0 = 89k stays)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--flushes", type=int, default=6, help="buffered-aggregation flush budget")
    ap.add_argument(
        "--latency", default="lognormal:0.6",
        help="latency model spec: constant[:t], lognormal[:sigma], "
        "pareto[:alpha], trace[:per_sample]",
    )
    ap.add_argument("--dropout", type=float, default=0.05, help="per-dispatch failure probability")
    ap.add_argument(
        "--aggregator", default="fedbuff:0.25",
        help="buffered aggregator spec ('fedbuff:K' with an int count or a "
        "fraction of the federation, 'hierarchical-async:R'); default "
        "flushes every quarter-federation",
    )
    args = ap.parse_args()

    cohort = generate_cohort(CohortConfig().scaled(args.scale), seed=args.seed)
    clients = build_client_datasets(cohort)
    model_cfg = GRUConfig(hidden_dim=8, num_layers=1)
    loss_fn = make_loss_fn(model_cfg)
    params0 = init_gru(jax.random.key(args.seed), model_cfg)
    print(f"cohort: {len(cohort.y):,} stays, {len(clients)} hospitals")
    print(f"latency={args.latency} dropout={args.dropout}")

    results = {}
    for name, recruitment in (("all-clients", "all"), ("recruited", "nu-greedy")):
        federation = AsyncFederation(
            AsyncFederationConfig(
                rounds=args.flushes,
                local_epochs=1,
                batch_size=16,
                recruitment=recruitment,
                aggregator=args.aggregator,
                latency=args.latency,
                dropout=args.dropout,
                seed=args.seed,
            ),
            clients,
            loss_fn,
            AdamW(learning_rate=5e-3, weight_decay=5e-3),
        )
        out = federation.run(params0)
        stats = federation.last_run_stats
        results[name] = out
        print(f"--- {name}: {out.federation_ids.size} clients ---")
        for r in out.history:
            print(
                f"  flush {r.round_index}: virtual_t={r.virtual_time:7.2f}s "
                f"loss={r.mean_local_loss:.4f} staleness={r.staleness:.2f} "
                f"({len(r.participant_ids)} updates)"
            )
        print(
            f"  {stats['tasks']} tasks, {stats['dropped']} dropped, "
            f"virtual time {stats['virtual_time']:.2f}s "
            f"(host {out.total_wall_time_s:.1f}s)"
        )

    target, times = shared_time_to_target(
        {name: out.history for name, out in results.items()}
    )
    t_all, t_rec = times["all-clients"], times["recruited"]
    if t_all is None or t_rec is None or t_rec == 0:
        print(f"\nno shared finite target reached (target={target}); "
              "try more --flushes or a lower --dropout")
        return
    sizes = {name: int(out.federation_ids.size) for name, out in results.items()}
    print(
        f"\nTime to loss<={target:.4f} on the simulated clock: "
        f"all-clients {t_all:.2f}s vs recruited {t_rec:.2f}s "
        f"({sizes['recruited']} of {sizes['all-clients']} hospitals, "
        f"{t_all / t_rec:.2f}x sooner)"
    )
    stale = [out.summary()["mean_staleness"] for out in results.values()]
    print(f"mean update staleness: {stale[0]:.2f} / {stale[1]:.2f} parameter versions")


if __name__ == "__main__":
    main()
